import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes; record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

The XLA_FLAGS line above MUST precede every other import: jax locks the
device count at first backend initialization.  Reports land in
experiments/dryrun/<arch>__<shape>__<mesh>[__<opt>].json.

Roofline extraction (single-pod only): XLA cost_analysis counts a
while-loop body once, so per-layer cost is measured by lowering twice
(scan unroll=1 vs unroll=2) and extrapolating outside + groups*delta
(launch/hlo_analysis.extrapolate).
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS
from repro.distributed import sharding
from repro.distributed.sharding import (batch_spec, param_shardings,
                                        use_mesh, zero_shardings)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import transformer
from repro.models.base import ArchConfig, get_arch

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _batch_shardings(mesh, batch_sds: Dict[str, Any]):
    bspec = batch_spec(mesh)
    axes = tuple(bspec)[0] if len(tuple(bspec)) else None
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        if a is not None:
            size *= mesh.shape.get(a, 1)
    out = {}
    for k, v in batch_sds.items():
        lead = axes if (v.shape[0] % size == 0 and v.shape[0] >= size) else None
        out[k] = NamedSharding(mesh, P(*((lead,) + (None,) * (v.ndim - 1))))
    return out


def _cache_shardings(mesh, caches_sds, batch: int, mode: str = "minor"):
    """Decode-cache shardings — the tuple-cache-aware rules live in
    :func:`repro.distributed.sharding.cache_shardings` (shared with the
    serving fleet's tensor-parallel replica groups)."""
    return sharding.cache_shardings(caches_sds, mesh, batch, mode=mode)


def _apply_opt(cfg: ArchConfig, opt: str) -> ArchConfig:
    """Perf-iteration knobs (EXPERIMENTS.md §Perf)."""
    sharding.set_moe_mode("ep2d" if "ep2d" in opt else "fsdp")
    transformer.set_remat_policy("dots" if "rematdots" in opt else "full")
    if "chunk128" in opt:
        cfg = dataclasses.replace(cfg, ssm_chunk=128)
    if "chunk512" in opt:
        cfg = dataclasses.replace(cfg, ssm_chunk=512)
    if "cap10" in opt:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=1.0)
    return cfg


def _lower(cfg: ArchConfig, shape: M.ShapeSpec, mesh, opt: str = "base"):
    cfg = _apply_opt(cfg, opt)
    cache_mode = "seq" if "seqshard" in opt else "minor"
    specs = M.input_specs(cfg, shape)
    with use_mesh(mesh):
        pshard = param_shardings(specs["params"], mesh)
        if shape.kind == "train":
            step = M.make_train_step(cfg)
            oshard = zero_shardings(specs["opt_state"], specs["params"], mesh)
            bshard = _batch_shardings(mesh, specs["batch"])
            jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None))
            return jitted.lower(specs["params"], specs["opt_state"],
                                specs["batch"])
        if shape.kind == "prefill":
            fn = M.make_prefill(cfg)
            bshard = _batch_shardings(mesh, specs["batch"])
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            return jitted.lower(specs["params"], specs["batch"])
        fn = M.make_decode_step(cfg)
        cshard = _cache_shardings(mesh, specs["caches"], shape.global_batch,
                                  mode=cache_mode)
        tshard = _batch_shardings(mesh, {"token": specs["token"]})["token"]
        args = [specs["params"], specs["caches"], specs["token"],
                specs["index"]]
        in_sh = [pshard, cshard, tshard, None]
        if cfg.family == "encdec":
            args.append(specs["enc_out"])
            in_sh.append(_batch_shardings(mesh, {"e": specs["enc_out"]})["e"])
        jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                         out_shardings=(None, cshard))
        return jitted.lower(*args)


def _even_groups_cfg(cfg: ArchConfig) -> ArchConfig:
    g = transformer.num_groups(cfg)
    if g % 2 == 0:
        return cfg
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, num_layers=cfg.num_layers + cfg.attn_every)
    return dataclasses.replace(cfg, num_layers=cfg.num_layers + 1)


def _model_flops_per_chip(cfg: ArchConfig, shape: M.ShapeSpec, chips: int) -> float:
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / chips
    return 2.0 * n_act * shape.global_batch / chips  # decode: 1 token/seq


def roofline_cell(arch_id: str, shape_name: str,
                  cfg_override: Optional[ArchConfig] = None,
                  opt: str = "base",
                  verbose: bool = True) -> Dict[str, Any]:
    """Single-pod roofline via the unroll-delta method."""
    cfg = cfg_override or get_arch(arch_id)
    shape = M.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    cfg_even = _even_groups_cfg(cfg)
    results = []
    for u in (1, 2):
        transformer.set_scan_unroll(u)
        try:
            compiled = _lower(cfg_even, shape, mesh, opt=opt).compile()
        finally:
            transformer.set_scan_unroll(1)
            sharding.set_moe_mode("fsdp")
        results.append(hlo_analysis.analyze(compiled))
    groups = transformer.num_groups(cfg)
    rf = hlo_analysis.extrapolate(results[0], results[1], groups)
    chips = mesh.devices.size
    mf = _model_flops_per_chip(cfg, shape, chips)
    out = rf.to_dict()
    out["model_flops"] = mf
    out["useful_ratio"] = mf / rf.flops if rf.flops else 0.0
    if verbose:
        print(f"[roofline] {arch_id} x {shape_name} ({opt}): dominant={rf.dominant} "
              f"compute={rf.compute_s * 1e3:.2f}ms memory={rf.memory_s * 1e3:.2f}ms "
              f"collective={rf.collective_s * 1e3:.2f}ms "
              f"useful={out['useful_ratio']:.2f}", flush=True)
    return out


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
               compile_: bool = True, roofline: bool = False,
               verbose: bool = True) -> Dict[str, Any]:
    cfg = get_arch(arch_id)
    shape = M.SHAPES[shape_name]
    ok, why = M.shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    report: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "status": "skip", "reason": why,
    }
    if not ok:
        return report
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered = _lower(cfg, shape, mesh)
    report["lower_s"] = round(time.time() - t0, 1)
    if not compile_:
        report["status"] = "lowered"
        return report
    t1 = time.time()
    compiled = lowered.compile()
    report["compile_s"] = round(time.time() - t1, 1)
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                report[k] = int(v)
    report["status"] = "ok"
    if verbose:
        print(f"[dryrun] {arch_id} x {shape_name} x {mesh_name}: "
              f"lower {report['lower_s']}s compile {report['compile_s']}s "
              f"args/device={report.get('argument_size_in_bytes', 0) / 2**30:.2f}GiB",
              flush=True)
    if roofline and not multi_pod:
        report["roofline"] = roofline_cell(arch_id, shape_name,
                                           verbose=verbose)
    return report


def save_report(report: Dict[str, Any], opt: str = "base") -> pathlib.Path:
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "" if opt == "base" else f"__{opt}"
    fn = REPORT_DIR / (f"{report['arch']}__{report['shape']}__"
                       f"{report['mesh']}{suffix}.json")
    fn.write_text(json.dumps(report, indent=2))
    return fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(M.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = [(a, s, mp) for a in archs for s in shapes for mp in meshes]

    failures = 0
    for a, s, mp in cells:
        try:
            rep = lower_cell(a, s, multi_pod=mp, compile_=not args.no_compile,
                             roofline=args.roofline)
        except Exception as e:  # a failure here is a bug in our sharding
            traceback.print_exc()
            rep = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "fail", "error": str(e)[:500]}
            failures += 1
        save_report(rep)
        if rep["status"] == "skip":
            print(f"[dryrun] {a} x {s}: SKIP ({rep['reason']})", flush=True)
    print(f"[dryrun] done: {len(cells)} cells, {failures} failures", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
