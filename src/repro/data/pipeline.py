"""Deterministic synthetic token pipeline.

Generates reproducible pseudo-text token streams (Zipfian unigram mix
with short-range repetition structure so models have learnable signal),
sharded by host, with background-free double buffering (prefetch=2) —
the same interface a real tokenized-shard loader would expose, so
launch/train.py is loader-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    num_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    repeat_prob: float = 0.3   # induces learnable bigram structure


class SyntheticTokens:
    """Infinite deterministic stream; step -> batch is a pure function of
    (seed, step, host), so restarts resume exactly (fault tolerance)."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide among hosts")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        # fixed Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, cfg.zipf_a)
        self._p = (p / p.sum()).astype(np.float64)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        B, S = self.local_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._p)
        # short-range repetition: with prob repeat_prob, copy t-2
        rep = rng.random((B, S + 1)) < cfg.repeat_prob
        toks[:, 2:] = np.where(rep[:, 2:], toks[:, :-2], toks[:, 2:])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, :-1]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(vocab_size: int, seq_len: int, global_batch: int,
                  seed: int = 1234, start_step: int = 0,
                  ) -> Iterator[Dict[str, np.ndarray]]:
    ds = SyntheticTokens(DataConfig(vocab_size, seq_len, global_batch, seed))
    step = start_step
    while True:
        yield ds.batch_at(step)
        step += 1
